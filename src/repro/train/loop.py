"""Host-side epoch training shell — the paper's Algorithm 1 end to end.

The ``Trainer`` is a thin host loop over ``train/engine.py::StepEngine``: it
owns only the HOST decisions — the adaptive-batch controller, the data
cursor, checkpoint/resume, and eval cadence. All device work (the SGD step,
the diversity-tier accumulation, buffer donation, the per-bucket compile
cache) lives in the engine; each mini-batch is one SGD step (exactly
Algorithm 1: adapting the batch size changes the *step* granularity), and
the only per-step host transfer is the scalar loss.

API stability: the ``Trainer`` constructor and ``run``/``run_epoch``/
``save``/``resume`` signatures are unchanged from the pre-engine version —
examples and downstream code keep working; ``trainer.params`` etc. are now
read-only views of the engine-owned ``TrainState``.

Elastic mode (``elastic=MeshLadder(...)``): the ladder co-adapts the device
footprint with the batch size — at the same epoch boundary that resizes the
batch, the state is resharded onto the widest rung whose dp width keeps the
per-device microbatch >= the ladder granule (``repro.elastic``), and the
engine's compile cache keys by (bucket, rung).  The feed path double-buffers
device transfers (``data.pipeline.prefetch``; ``prefetch=False`` reverts to
the synchronous put-per-step loop with an identical trajectory).

Checkpointing captures the FULL adaptive state; ``Trainer.resume()`` restores
mid-training with the identical remaining trajectory (tests assert this).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt import CheckpointManager
from repro.core import AdaptiveBatchController, diversity
from repro.data import ArrayDataset, Cursor, EpochLoader
from repro.data.pipeline import prefetch as prefetch_iter, put_global_batch
from repro.dist.plan import current_plan
from repro.elastic import MeshLadder, place, reshard
from repro.optim import Optimizer
from repro.train.engine import ModelFns, StepEngine, eval_fn_for
from repro.train.state import TrainState, init_state
from repro.train.step import epoch_end_host
from repro.utils.logging import get_logger

log = get_logger("train")

__all__ = ["ModelFns", "EpochRecord", "Trainer"]


@dataclasses.dataclass
class EpochRecord:
    epoch: int
    batch_size: int
    lr: float
    train_loss: float
    val_loss: float
    val_metrics: dict
    diversity: float | None
    steps: int
    wall_s: float


class Trainer:
    def __init__(
        self,
        fns: ModelFns,
        params: Any,
        optimizer: Optimizer,
        controller: AdaptiveBatchController,
        train_data: ArrayDataset,
        val_data: ArrayDataset,
        *,
        estimator: str = "exact",  # exact | gram | moment | oracle | none
        seed: int = 0,
        psn_microbatch: int = 256,
        ckpt: CheckpointManager | None = None,
        ckpt_every: int = 0,
        donate: bool = True,
        engine: StepEngine | None = None,
        elastic: MeshLadder | None = None,
        prefetch: bool = True,
    ):
        self.fns = fns
        self.optimizer = optimizer
        self.controller = controller
        self.train_data = train_data
        self.val_data = val_data
        self.estimator = estimator
        self.seed = seed
        self.psn_microbatch = psn_microbatch  # exact-tier vmap width / oracle chunk
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.cursor = Cursor()
        self.history: list[EpochRecord] = []
        # Donation invalidates the buffers passed to each step, so the state
        # lives in exactly one place: self.state, replaced every step
        # (init_state makes the leaves donation-ready jax Arrays).
        self.state: TrainState = init_state(params, optimizer)
        self._plan = current_plan()
        if elastic is not None and self._plan is not None:
            raise ValueError(
                "Trainer(elastic=...) under an ambient dist plan is ambiguous: "
                "the ladder owns the sharding plan per rung — drop the "
                "use_plan context (or the elastic ladder)"
            )
        self._elastic = elastic
        self._rung = None
        self._prefetch = prefetch
        self._shardings: dict[tuple[int, int], Any] = {}
        self.engine = engine or StepEngine.for_model_fns(
            fns,
            optimizer,
            estimator=estimator,
            diversity_on=controller.needs_diversity,
            dp_size=self._plan.dp_size if self._plan else 1,
            donate=donate,
            psn_chunk=psn_microbatch,
        )
        # an injected engine may lack an eval fn; the Trainer owns the fns
        self.engine.ensure_eval_fn(eval_fn_for(fns))
        if self._elastic is not None:
            # initial placement: the rung for the starting batch size
            self._ensure_rung(controller.batch_size)

    # -- read-only views of the engine-owned state (API compatibility) -------
    @property
    def params(self):
        return self.state.params

    @property
    def opt_state(self):
        return self.state.opt_state

    @property
    def div_state(self):
        return self.state.div_state

    @property
    def rung(self):
        """The live elastic ladder rung (None outside elastic mode)."""
        return self._rung

    # ------------------------------------------------------------------
    @property
    def _live_plan(self):
        """The plan batches/state live on: the elastic rung's when a ladder
        drives the run, else the ambient dist plan (None single-device)."""
        return self._rung.plan if self._rung is not None else self._plan

    def _ensure_rung(self, batch_size: int) -> None:
        """Elastic transition: move the state onto the ladder rung for
        ``batch_size`` — called at the same epoch boundary that resizes the
        batch. Strict no-op when the rung is unchanged (reshard returns the
        identical state object)."""
        if self._elastic is None:
            return
        rung = self._elastic.rung_for_batch(batch_size)
        if self._rung is not None and rung.index == self._rung.index:
            return
        src = self._rung
        # the initial placement must NOT donate: the state still aliases the
        # caller-passed params at that point (transitions own their buffers)
        self.state = reshard(
            self.state, src.plan if src else None, rung.plan,
            donate=self.engine.donate and src is not None,
        )
        self._rung = rung
        self.engine.rung = rung.index
        if src is not None:  # initial placement is not a transition
            self.engine.stats.reshards += 1
            log.info("elastic: rung %d -> %d (dp %d -> %d) for batch %d",
                     src.index, rung.index, src.dp, rung.dp, batch_size)

    def _batch_sharding(self, leading: int):
        """NamedSharding over the live plan's dp axes, if one divides the
        batch (memoized by (leading dim, rung) — constant within an epoch)."""
        plan = self._live_plan
        if plan is None:
            return None
        key = (leading, self._rung.index if self._rung is not None else -1)
        if key not in self._shardings:
            self._shardings[key] = (
                NamedSharding(plan.mesh, P(tuple(plan.dp)))
                if leading % plan.dp_size == 0 else None
            )
        return self._shardings[key]

    def _put(self, batch_np: dict) -> dict:
        leading = len(next(iter(batch_np.values())))
        return put_global_batch(batch_np, self._batch_sharding(leading))

    def _oracle_diversity(self) -> float:
        batches = (
            {k: jnp.asarray(v) for k, v in self.train_data.get(idx).items()}
            for idx in np.array_split(
                np.arange(len(self.train_data)),
                max(1, len(self.train_data) // self.psn_microbatch),
            )
        )
        return float(
            diversity.dataset_diversity(
                self.fns.example_loss, self.state.params, batches
            )
        )

    # ------------------------------------------------------------------
    def run_epoch(self) -> EpochRecord:
        t0 = time.time()
        bsz = self.controller.batch_size
        self._ensure_rung(bsz)
        lr = jnp.float32(self.controller.lr)
        loader = EpochLoader(
            self.train_data, bsz, epoch=self.cursor.epoch, seed=self.seed,
            start_batch=self.cursor.batch_index,
        )
        feed = (
            prefetch_iter(loader, put=self._put)
            if self._prefetch else (self._put(b) for b in loader)
        )
        losses = []
        for batch in feed:
            self.state, metrics = self.engine.step(self.state, batch, lr)
            losses.append(float(metrics["loss"]))
            self.cursor.batch_index += 1

        # epoch boundary ------------------------------------------------
        delta = None
        if self.controller.needs_diversity:
            if self.estimator == "oracle":
                delta = self._oracle_diversity()
                _, self.state = epoch_end_host(self.state, "moment")
            elif self.estimator in ("exact", "gram", "moment"):
                delta, self.state = epoch_end_host(self.state, self.estimator)
            else:
                # estimator='none' under a diversity-driven policy: degenerate
                # but supported — the accumulators were never fed, so the
                # estimate is 0.0 (matches the pre-engine loop).
                delta, self.state = epoch_end_host(self.state, "exact")
        decision = self.controller.on_epoch_end(delta)

        val = self._put(self.val_data.get(np.arange(len(self.val_data))))
        val_loss, val_metrics = self.engine.evaluate(self.state.params, val)
        rec = EpochRecord(
            epoch=self.cursor.epoch,
            batch_size=decision.batch_size,
            lr=decision.lr,
            train_loss=float(np.mean(losses)) if losses else float("nan"),
            val_loss=float(val_loss),
            val_metrics={k: float(v) for k, v in val_metrics.items()},
            diversity=delta,
            steps=len(losses),
            wall_s=time.time() - t0,
        )
        self.history.append(rec)
        self.cursor.epoch += 1
        self.cursor.batch_index = 0
        if self.ckpt and self.ckpt_every and self.cursor.epoch % self.ckpt_every == 0:
            self.save()
        return rec

    def run(self, epochs: int, verbose: bool = True) -> list[EpochRecord]:
        for _ in range(epochs):
            rec = self.run_epoch()
            if verbose:
                log.info(
                    "epoch %d: loss=%.4f val=%.4f metrics=%s m=%d lr=%.4g div=%s",
                    rec.epoch, rec.train_loss, rec.val_loss, rec.val_metrics,
                    rec.batch_size, rec.lr,
                    f"{rec.diversity:.4g}" if rec.diversity is not None else "-",
                )
        return self.history

    # ------------------------------------------------------------------
    def save(self):
        assert self.ckpt is not None
        self.ckpt.save(
            step=self.cursor.epoch,
            state={
                "params": self.state.params,
                "opt_state": self.state.opt_state,
                "div_state": self.state.div_state,
            },
            extra={
                "controller": self.controller.state_dict(),
                "cursor": self.cursor.state_dict(),
                "history": [dataclasses.asdict(r) for r in self.history],
                "step": int(self.state.step),
            },
        )

    def resume(self) -> bool:
        assert self.ckpt is not None
        if self.ckpt.latest_step() is None:
            return False
        # Checkpoints hold logical host tensors; restore places them onto
        # whatever plan is live (elastic.reshard.place) — a checkpoint saved
        # on one rung resumes on any other, or on no plan at all.
        out, extra = self.ckpt.restore(
            {"params": self.state.params, "opt_state": self.state.opt_state,
             "div_state": self.state.div_state}
        )
        self.controller.load_state_dict(extra["controller"])
        self.cursor.load_state_dict(extra["cursor"])
        self.history = [EpochRecord(**r) for r in extra.get("history", [])]
        if self._elastic is not None:
            # the restored batch size decides the rung, not the one this
            # (possibly fresh) Trainer started on — pick it BEFORE placing so
            # the state is transferred exactly once
            rung = self._elastic.rung_for_batch(self.controller.batch_size)
            self._rung = rung
            self.engine.rung = rung.index
        self.state = place(
            TrainState(
                params=out["params"],
                opt_state=out["opt_state"],
                div_state=out["div_state"],
                step=np.asarray(extra.get("step", 0), np.int32),
            ),
            self._live_plan,
        )
        log.info("resumed from epoch %d", self.cursor.epoch)
        return True
