"""StepEngine — the single compiled training path.

Every trainer in the repo (the host ``Trainer`` shell, ``launch/train.py``,
the multi-pod dry-run, ``examples/train_lm.py``) drives this engine instead
of building its own jits. The engine owns:

  * a compile cache keyed by the power-of-2 batch/``num_micro`` bucket
    (``core/batch_policy.bucket``): a DiveBatch run that adapts the batch
    size across the whole lattice compiles at most
    ``log2(m_max/granule) + 1`` step programs, and a resize back onto an
    already-seen bucket is a cache hit (zero recompilation);
  * buffer donation: steps are compiled with ``donate_argnums=(0,)`` on the
    ``TrainState``, so params/optimizer/diversity buffers are updated in
    place — the steady-state HBM footprint is one state, not two;
  * the scan-based step from ``train/step.py::make_train_step`` with the
    diversity tier folded inside the jit — an epoch performs no per-step
    host transfer beyond the scalar metrics;
  * ``EngineStats``: bucket hit/miss counts, compile count and seconds,
    step count and wall time — the record benchmarks and tests consume.

Sharding: the engine is plan-agnostic. Under ``dist.use_plan`` the caller
passes explicit ``in_shardings``/``out_shardings`` (the dry-run does) or
simply feeds sharded arrays and lets GSPMD propagate (the host path does);
outside a plan everything runs single-device. The engine code is identical
in all three cases — that is the point.
"""

from __future__ import annotations

import dataclasses
import inspect
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.obs import metrics as metrics_lib
from repro.obs import runlog as runlog_lib
from repro.obs import trace as trace_lib
from repro.optim import Optimizer
from repro.train import step as step_lib
from repro.train.state import TrainState

PyTree = Any


@dataclasses.dataclass
class ModelFns:
    """Pure functions defining a (non-LM) trainee.

    batch_loss(params, batch) -> scalar mean loss
    example_loss(params, example) -> scalar (per-sample; for exact/oracle)
    metrics(params, batch) -> dict (e.g. accuracy)   [optional]
    probe_loss(params, probes, batch) -> (loss, acts)  [gram tier, optional]
    probe_specs(params, batch_size) -> probes pytree   [gram tier, optional]
    """

    batch_loss: Callable
    example_loss: Callable | None = None
    metrics: Callable | None = None
    probe_loss: Callable | None = None
    probe_specs: Callable | None = None


def eval_fn_for(fns: ModelFns) -> Callable:
    """The standard eval over ModelFns: (params, batch) -> (loss, metrics)."""

    def eval_fn(params, batch):
        loss = fns.batch_loss(params, batch)
        metrics = fns.metrics(params, batch) if fns.metrics else {}
        return loss, metrics

    return eval_fn


class EngineStats(metrics_lib.StatsView):
    """Observable engine behaviour (consumed by benchmarks/ and tests).

    ``compiles`` counts *step* compilations — one per distinct (bucket, rung,
    tier, batch-signature) tuple; with a fixed batch schema (the normal case)
    that is one per (bucket, rung, tier), so ``compiles ==
    len(set(zip(buckets, rungs, tiers)))`` and the policy's ``max_buckets``
    bound applies per (rung, tier) — ``max_buckets * num_rungs * num_tiers``
    worst case, one per bucket when rung and tier are functions of the
    bucket/run. ``bucket_hits``/``bucket_misses`` count cache lookups;
    ``buckets`` lists the bucket key of each compile in order (a key repeats
    only if the batch schema, rung, or tier changed within a bucket);
    ``reshards`` counts rung transitions applied to the engine-owned state.

    The scalar fields are emitting views over the ``repro.obs.metrics``
    registry: each instance claims a fresh ``train.engine.<n>`` namespace and
    ``REGISTRY.snapshot()`` sees every engine in the process; the legacy
    attribute surface (``stats.compiles += 1``, ``as_dict()``) is unchanged
    (the equivalence test in tests/test_obs.py pins both).
    """

    _COUNTERS = ("compiles", "bucket_hits", "bucket_misses", "steps", "reshards")
    # Time spent *dispatching* steps (``dispatch_wall_s``). jax execution is
    # async: the engine does not block on results (callers decide when to
    # read), so this is NOT end-to-end throughput — benchmarks measure that
    # with their own wall clock around a blocking loop
    # (benchmarks/bench_engine.py).
    _GAUGES = ("compile_s", "dispatch_wall_s")

    def __init__(self, donate: bool = True, *,
                 registry: metrics_lib.Registry | None = None):
        self.donate = donate
        #: the bucket key of each compile, in order
        self.buckets: list[int] = []
        # the rung token active at each compile, parallel to ``buckets`` (all
        # None outside elastic mode). Distinct (bucket, rung) pairs bound the
        # compile count: num_buckets x num_rungs worst case, and exactly one
        # per bucket when the rung is a pure function of the bucket (a
        # MeshLadder driven by the same granule as the batch policy).
        self.rungs: list = []
        # the estimator-tier token active at each compile, parallel to
        # ``buckets`` (None for engines whose build is not tier-parameterised).
        # A Decision.estimator flip is a new cache key, not an engine rebuild:
        # flipping back onto an already-compiled (bucket, rung, tier) is a hit.
        self.tiers: list = []
        self._init_metrics("train.engine", registry)

    @property
    def dispatch_steps_per_sec(self) -> float:
        return self.steps / self.dispatch_wall_s if self.dispatch_wall_s > 0 else 0.0

    def as_dict(self) -> dict:
        return {
            "compiles": self.compiles,
            "bucket_hits": self.bucket_hits,
            "bucket_misses": self.bucket_misses,
            "steps": self.steps,
            "compile_s": self.compile_s,
            "reshards": self.reshards,
            "dispatch_wall_s": self.dispatch_wall_s,
            "donate": self.donate,
            "buckets": list(self.buckets),
            "rungs": list(self.rungs),
            "tiers": list(self.tiers),
            "dispatch_steps_per_sec": self.dispatch_steps_per_sec,
        }


class StepEngine:
    """Bucketed, donation-aware compile cache around ``make_train_step``.

    ``build_step(key)`` returns the (untraced) step function for one bucket
    key; ``bucket_of(batch)`` maps a host batch to its key (default: the
    leading dim of the first leaf, which the batch policies already snap to
    the pow2 lattice).  ``build_step`` may instead take ``(key, tier)`` —
    then the engine is *tier-parameterised*: setting ``engine.tier`` keys
    the compile cache by (bucket, rung, tier), so a ``Decision.estimator``
    flip compiles the new tier's buckets on first use and every flip back
    onto a seen tier is a cache hit (the old behaviour rebuilt the whole
    jit family per flip).  A third positional parameter, ``(key, tier,
    rung)``, makes the build *rung-aware*: the active ``engine.rung`` token
    is passed through so the build can return a structurally different step
    program per rung (``repro.pod.PodLadder`` compiles a shard_map'd
    compressed cross-pod step on ``pods > 1`` rungs and the plain step
    elsewhere); the jit cache then keys by (bucket, tier, rung).
    """

    def __init__(
        self,
        build_step: Callable[[int], Callable],
        *,
        bucket_of: Callable[[PyTree], int] | None = None,
        donate: bool = True,
        in_shardings=None,
        out_shardings=None,
        eval_fn: Callable | None = None,
        tracer=None,
        runlog=None,
    ):
        self._build = build_step
        # telemetry sinks (repro.obs); the null defaults make every emit a
        # strict no-op, and hot paths additionally guard on .enabled
        self.tracer = tracer if tracer is not None else trace_lib.NULL
        self.runlog = runlog if runlog is not None else runlog_lib.NULL
        try:
            sig_params = inspect.signature(build_step).parameters.values()
            # only genuinely positional parameters count — a (key, **opts)
            # or keyword-only second arg cannot receive a positional tier
            n_params = sum(
                1 for p in sig_params
                if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)
            )
        except (TypeError, ValueError):  # builtins/partials without signature
            n_params = 1
        #: whether build_step accepts a tier argument (see class docstring)
        self.tiered = n_params >= 2
        #: whether build_step also accepts the rung token (see class docstring)
        self.rung_aware = n_params >= 3
        # The active estimator-tier token (any hashable; the Trainer uses the
        # tier name). Part of the executable cache key exactly like ``rung``.
        # None = the build's own default tier (non-tiered engines stay None).
        self.tier = None
        self._bucket_of = bucket_of or (
            lambda batch: int(jax.tree.leaves(batch)[0].shape[0])
        )
        # Elastic mode: the current ladder-rung token (any hashable; the
        # Trainer uses the rung index). It is part of the executable cache
        # key — AOT executables are sharding-exact, so a state resharded onto
        # a different rung must never dispatch into another rung's program.
        # None (the default) keys every step identically: non-elastic
        # callers see the pure (bucket, signature) cache.
        self.rung = None
        self.donate = donate
        self._in_shardings = in_shardings
        self._out_shardings = out_shardings
        self._jits: dict[tuple, Callable] = {}
        self._compiled: dict[tuple, Callable] = {}
        self._eval_fn = eval_fn
        self._eval_jit = None
        self.stats = EngineStats(donate=donate)

    # -- compile cache -------------------------------------------------------
    def jitted(self, key: int) -> Callable:
        """The jax.jit-wrapped step for bucket ``key`` at the active tier
        (not yet compiled — AOT callers like the dry-run lower/compile it
        themselves)."""
        if self.tier is not None and not self.tiered:
            raise ValueError(
                "engine.tier was set but build_step takes no tier argument; "
                "tier flips on hand-built engines need a (key, tier) build"
            )
        jkey = (key, self.tier, self.rung if self.rung_aware else None)
        if jkey not in self._jits:
            kwargs = {}
            if self._in_shardings is not None:
                kwargs["in_shardings"] = self._in_shardings
            if self._out_shardings is not None:
                kwargs["out_shardings"] = self._out_shardings
            if self.donate:
                kwargs["donate_argnums"] = (0,)
            if self.rung_aware:
                fn = self._build(key, self.tier, self.rung)
            elif self.tiered:
                fn = self._build(key, self.tier)
            else:
                fn = self._build(key)
            self._jits[jkey] = jax.jit(fn, **kwargs)
        return self._jits[jkey]

    def _executable(self, key: int, state: TrainState, batch: PyTree, lr):
        # AOT executables are shape- and sharding-exact, so the cache key
        # carries the full batch signature, the rung, and the estimator tier,
        # not just the bucket: batches agreeing on leading dim but differing
        # in trailing shape / dtype / structure / mesh rung / step program
        # get their own compile instead of dispatching into an incompatible
        # executable.
        sig = (
            key,
            self.rung,
            self.tier,
            jax.tree.structure(batch),
            tuple((leaf.shape[1:], str(leaf.dtype)) for leaf in jax.tree.leaves(batch)),
        )
        if sig in self._compiled:
            self.stats.bucket_hits += 1
            return self._compiled[sig]
        self.stats.bucket_misses += 1
        t0 = time.perf_counter()
        # AOT-compile so the compile count/time is exact, not inferred from
        # jit retrace behaviour.
        with self.tracer.span("compile", scope="train", bucket=key,
                              rung=self.rung, tier=str(self.tier)):
            compiled = self.jitted(key).lower(state, batch, lr).compile()
        dt = time.perf_counter() - t0
        if self.runlog.enabled:
            self.runlog.emit("compile", scope="train", what=f"bucket={key}",
                             seconds=dt, bucket=key, rung=self.rung,
                             tier=str(self.tier))
        self.stats.compile_s += dt
        self.stats.compiles += 1
        self.stats.buckets.append(key)
        self.stats.rungs.append(self.rung)
        self.stats.tiers.append(self.tier)
        self._compiled[sig] = compiled
        return compiled

    # -- stepping ------------------------------------------------------------
    def step(
        self, state: TrainState, batch: PyTree, lr
    ) -> tuple[TrainState, dict]:
        """One optimizer step at whatever bucket ``batch`` lands on.

        Donation invalidates the buffers of the *passed-in* state — callers
        must hold only the returned state (the Trainer does).
        """
        key = self._bucket_of(batch)
        lr = jnp.asarray(lr, jnp.float32)
        fn = self._executable(key, state, batch, lr)
        tr = self.tracer
        t0 = time.perf_counter()
        # the disabled path is one attribute load + branch (overhead guard
        # in tests/test_obs.py pins it): no span object, no clock beyond the
        # pre-existing dispatch_wall_s pair, no host transfer
        if tr.enabled:
            with tr.span("dispatch", bucket=key, rung=self.rung,
                         tier=str(self.tier), step_num=self.stats.steps):
                out = fn(state, batch, lr)
        else:
            out = fn(state, batch, lr)
        self.stats.dispatch_wall_s += time.perf_counter() - t0
        self.stats.steps += 1
        return out

    def evaluate(self, params: PyTree, batch: PyTree):
        """(loss, metrics) on a batch — cached jit, params NOT donated."""
        if self._eval_fn is None:
            raise ValueError("engine was built without an eval_fn")
        if self._eval_jit is None:
            self._eval_jit = jax.jit(self._eval_fn)
        return self._eval_jit(params, batch)

    def ensure_eval_fn(self, eval_fn: Callable) -> None:
        """Install ``eval_fn(params, batch) -> (loss, metrics)`` if the engine
        has none — lets the Trainer accept hand-built/injected engines."""
        if self._eval_fn is None:
            self._eval_fn = eval_fn

    def reset_stats(self) -> None:
        self.stats = EngineStats(donate=self.donate)

    # -- constructors --------------------------------------------------------
    @classmethod
    def for_model_fns(
        cls,
        fns: ModelFns,
        optimizer: Optimizer,
        *,
        estimator: str = "moment",
        diversity_on: bool = True,
        dp_size: int = 1,
        donate: bool = True,
        psn_chunk: int | None = None,
        psn_impl: str = "auto",
        psn_interpret: bool | None = None,
    ) -> "StepEngine":
        """Engine over generic ``ModelFns`` (the paper's reference models).

        One bucket = one global batch size; ``num_micro`` is 1, so each batch
        is exactly one SGD step (Algorithm 1's step granularity) and the
        compiled program is arithmetically identical to the classic
        ``value_and_grad`` + update step.

        The build is tier-parameterised: the engine starts on ``estimator``
        and a later ``engine.tier = "gram"`` (a Decision.estimator flip)
        compiles that tier's buckets alongside the old ones — the (bucket,
        rung, tier) cache makes the flip back a hit.
        """
        injit = ("exact", "gram", "moment")

        def build(key: int, tier: str | None = None) -> Callable:
            est = tier if tier is not None else estimator
            track = diversity_on and est in injit
            return step_lib.make_train_step(
                None,
                optimizer,
                num_micro=1,
                dp_size=dp_size,
                diversity_on=track,
                loss_fn=fns.batch_loss,
                estimator=est if track else "moment",
                example_loss=fns.example_loss,
                probe_loss=fns.probe_loss,
                probe_specs=fns.probe_specs,
                psn_chunk=psn_chunk,
                psn_impl=psn_impl,
                psn_interpret=psn_interpret,
            )

        eng = cls(build, donate=donate, eval_fn=eval_fn_for(fns))
        if diversity_on and estimator in injit:
            # name the starting tier so a flip away and back shares the key
            eng.tier = estimator
        return eng

    @classmethod
    def for_lm(
        cls,
        cfg,
        optimizer: Optimizer,
        *,
        micro_batch: int | None = None,
        dp_size: int = 1,
        moe_groups: int = 1,
        diversity_on: bool = True,
        grad_accum_dtype=jnp.float32,
        donate: bool = True,
        in_shardings=None,
        out_shardings=None,
        attn_impl: str | None = None,
    ) -> "StepEngine":
        """Engine over the transformer LM loss (production path).

        One bucket = one ``num_micro`` (accumulation length); the microbatch
        shape is fixed per mesh, so with ``micro_batch`` given the bucket of
        a global batch of B sequences is ``B // micro_batch``.

        ``attn_impl`` overrides ``cfg.attn_impl`` for the training forward
        ("pallas" puts the flash kernel — forward AND recompute backward —
        on the kernels/attention.py lane).
        """
        if attn_impl is not None:
            cfg = cfg.replace(attn_impl=attn_impl)

        def build(num_micro: int, tier: str | None = None) -> Callable:
            return step_lib.make_train_step(
                cfg,
                optimizer,
                num_micro,
                dp_size=dp_size,
                moe_groups=moe_groups,
                diversity_on=diversity_on,
                grad_accum_dtype=grad_accum_dtype,
                **({"estimator": tier} if tier is not None else {}),
            )

        if micro_batch is None:
            # Without a microbatch size the bucket key (num_micro) cannot be
            # derived from a batch: AOT-only use via .jitted(num_micro).
            def bucket_of(batch):
                raise ValueError(
                    "StepEngine.for_lm was built without micro_batch: use "
                    ".jitted(num_micro) directly, or pass micro_batch= to "
                    "enable .step()"
                )
        else:

            def bucket_of(batch):
                b = int(jax.tree.leaves(batch)[0].shape[0])
                if b % micro_batch != 0:
                    # Two shapes must never share a cache key: the per-bucket
                    # executables are AOT-compiled and shape-exact.
                    raise ValueError(
                        f"global batch {b} is not a multiple of micro_batch "
                        f"{micro_batch}; batch sizes must land on the bucket "
                        f"lattice (core/batch_policy.bucket)"
                    )
                return max(b // micro_batch, 1)

        eng = cls(
            build,
            bucket_of=bucket_of,
            donate=donate,
            in_shardings=in_shardings,
            out_shardings=out_shardings,
        )
        if diversity_on:
            # name the default tier (make_train_step's "moment") so a flip
            # away and back lands on the warm key, exactly like for_model_fns
            eng.tier = "moment"
        return eng
