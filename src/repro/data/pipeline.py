"""Epoch-oriented, resumable, sharding-aware batch pipeline.

Design constraints coming from the paper + the multi-pod target:
  * the batch size changes at adaptation boundaries (epoch ends, or — via
    ``repro.adapt`` — mid-epoch ticks/events): an iterator is constructed
    per (epoch, batch-size) segment, and ``start_sample`` lets a mid-epoch
    resize continue the SAME epoch permutation at the exact sample offset
    the previous size stopped at;
  * determinism under restart: the permutation is a pure function of
    (seed, epoch), and the cursor (epoch, batch_index, sample_index) is
    checkpointed, so a resumed job sees the identical remaining batches;
  * sharding-awareness: each host materialises only its slice of the global
    batch; device placement uses a NamedSharding over the data axes.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Iterator

import jax
import numpy as np

from repro.data.synthetic import ArrayDataset


@dataclasses.dataclass
class Cursor:
    """Checkpointable position in the sample stream.

    ``sample_index`` is the number of samples consumed from the current
    epoch's permutation — the unit that stays meaningful when the batch size
    changes MID-epoch (``batch_index`` alone cannot say where the epoch is
    once steps have had different sizes).  Zero at every epoch boundary;
    pre-redesign checkpoints without the field load as zero.
    """

    epoch: int = 0
    batch_index: int = 0
    sample_index: int = 0

    def state_dict(self) -> dict:
        return {"epoch": self.epoch, "batch_index": self.batch_index,
                "sample_index": self.sample_index}

    def load_state_dict(self, d: dict) -> None:
        self.epoch, self.batch_index = int(d["epoch"]), int(d["batch_index"])
        self.sample_index = int(d.get("sample_index", 0))


def epoch_permutation(n: int, seed: int, epoch: int) -> np.ndarray:
    return np.random.default_rng((seed, epoch)).permutation(n)


class EpochLoader:
    """Iterates one epoch of ``dataset`` at a fixed global batch size.

    drop_remainder=True keeps every step shape-identical (required for the
    bucketed compile cache); the tail (< batch_size samples) rolls over by
    virtue of reshuffling next epoch — same convention as the paper's code.
    """

    def __init__(
        self,
        dataset: ArrayDataset,
        batch_size: int,
        epoch: int,
        seed: int = 0,
        start_batch: int = 0,
        drop_remainder: bool = True,
        shard_index: int = 0,
        shard_count: int = 1,
        start_sample: int | None = None,
        perm: np.ndarray | None = None,
    ):
        """``start_sample`` resumes the epoch's permutation at an arbitrary
        sample offset — the unit a MID-epoch batch-size change needs (the
        new loader continues the identical permutation exactly where the old
        size stopped).  Default: ``start_batch * batch_size``, the classic
        batch-aligned resume.

        ``perm`` supplies the epoch permutation precomputed (must equal
        ``epoch_permutation(len(dataset), seed, epoch)``): a caller opening
        several loaders for one epoch (one per mid-epoch resize segment)
        avoids re-running the O(n) shuffle per segment."""
        if batch_size % shard_count != 0:
            raise ValueError(
                f"global batch {batch_size} not divisible by shard_count {shard_count}"
            )
        self.dataset = dataset
        self.batch_size = int(batch_size)
        self.epoch = int(epoch)
        self.seed = int(seed)
        self.start_batch = int(start_batch)
        self.shard_index = int(shard_index)
        self.shard_count = int(shard_count)
        n = len(dataset)
        self.start_sample = (
            int(start_sample) if start_sample is not None
            else self.start_batch * self.batch_size
        )
        remaining = max(n - self.start_sample, 0)
        self.num_batches = (
            remaining // batch_size if drop_remainder else -(-remaining // batch_size)
        )
        self._perm = perm if perm is not None else epoch_permutation(n, seed, epoch)

    def __len__(self) -> int:
        return self.num_batches

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        per_shard = self.batch_size // self.shard_count
        for b in range(self.num_batches):
            lo = self.start_sample + b * self.batch_size + self.shard_index * per_shard
            idx = self._perm[lo : lo + per_shard]
            yield self.dataset.get(idx)


def put_global_batch(batch: dict[str, np.ndarray], sharding=None) -> dict[str, jax.Array]:
    """Device-put a host batch; with a NamedSharding this becomes the
    host-local shard of a global array (multi-host) or a sharded array
    (single-host multi-device)."""
    if sharding is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    return {k: jax.device_put(v, sharding) for k, v in batch.items()}


def prefetch(batches, put=put_global_batch, *, depth: int = 2,
             host_overlap: bool = False):
    """Double-buffered device feed: ``put`` (device transfer) of batch *b+1*
    is issued while step *b* executes.

    jax dispatch is async, so holding ``depth`` already-transferred batches
    ahead of the consumer overlaps host->device copies with device compute —
    the consumer never waits on a cold transfer. ``depth=1`` degenerates to
    the unbuffered ``put``-per-iteration loop; the yielded values (and
    therefore the training trajectory) are identical either way, only the
    transfer timing moves.

    ``host_overlap=True`` additionally moves the HOST side of producing a
    batch — the numpy gather/permutation inside ``batches`` itself — onto a
    background thread, so for large batches the indexing copy overlaps the
    device step too, not just the transfer.  The yielded sequence is
    identical (one producer, FIFO queue of the same ``depth``); closing the
    generator early (e.g. a mid-epoch resize abandoning the feed) stops the
    producer thread.
    """
    if depth < 1:
        raise ValueError(f"prefetch depth must be >= 1, got {depth}")
    if host_overlap:
        return _threaded_prefetch(batches, put, depth)
    return _dispatch_prefetch(batches, put, depth)


def _dispatch_prefetch(batches, put, depth: int):
    buf: collections.deque = collections.deque()
    for b in batches:
        buf.append(put(b))
        if len(buf) >= depth:
            yield buf.popleft()
    while buf:
        yield buf.popleft()


def _threaded_prefetch(batches, put, depth: int):
    """Producer thread runs gather (iterating ``batches``) AND ``put``;
    consumer drains a bounded FIFO.  Exceptions propagate; early close of
    the generator stops the producer."""
    import queue
    import threading

    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()
    done = object()  # sentinel
    error: list[BaseException] = []

    def _offer(item) -> bool:
        while not stop.is_set():
            try:
                q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def producer():
        try:
            for b in batches:
                if stop.is_set() or not _offer(put(b)):
                    return
        except BaseException as e:  # surfaced on the consumer side
            error.append(e)
        finally:
            _offer(done)

    thread = threading.Thread(target=producer, daemon=True, name="prefetch")
    thread.start()
    try:
        while True:
            item = q.get()
            if item is done:
                if error:
                    raise error[0]
                return
            yield item
    finally:
        stop.set()
        while not q.empty():  # unblock a producer stuck on a full queue
            try:
                q.get_nowait()
            except queue.Empty:
                break
        thread.join(timeout=10)


def microbatches(batch: dict[str, np.ndarray], micro_size: int):
    """Split a (host-side) batch into microbatches along axis 0."""
    n = len(next(iter(batch.values())))
    if n % micro_size != 0:
        raise ValueError(f"batch {n} not divisible by microbatch {micro_size}")
    for i in range(0, n, micro_size):
        yield {k: v[i : i + micro_size] for k, v in batch.items()}
