"""Epoch-oriented, resumable, sharding-aware batch pipeline.

Design constraints coming from the paper + the multi-pod target:
  * batch size changes at epoch boundaries (DiveBatch) -> the iterator is
    constructed per epoch with that epoch's global batch size;
  * determinism under restart: the permutation is a pure function of
    (seed, epoch), and the cursor (epoch, batch_index) is checkpointed, so a
    resumed job sees the identical remaining batches;
  * sharding-awareness: each host materialises only its slice of the global
    batch; device placement uses a NamedSharding over the data axes.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Iterator

import jax
import numpy as np

from repro.data.synthetic import ArrayDataset


@dataclasses.dataclass
class Cursor:
    """Checkpointable position in the sample stream."""

    epoch: int = 0
    batch_index: int = 0

    def state_dict(self) -> dict:
        return {"epoch": self.epoch, "batch_index": self.batch_index}

    def load_state_dict(self, d: dict) -> None:
        self.epoch, self.batch_index = int(d["epoch"]), int(d["batch_index"])


def epoch_permutation(n: int, seed: int, epoch: int) -> np.ndarray:
    return np.random.default_rng((seed, epoch)).permutation(n)


class EpochLoader:
    """Iterates one epoch of ``dataset`` at a fixed global batch size.

    drop_remainder=True keeps every step shape-identical (required for the
    bucketed compile cache); the tail (< batch_size samples) rolls over by
    virtue of reshuffling next epoch — same convention as the paper's code.
    """

    def __init__(
        self,
        dataset: ArrayDataset,
        batch_size: int,
        epoch: int,
        seed: int = 0,
        start_batch: int = 0,
        drop_remainder: bool = True,
        shard_index: int = 0,
        shard_count: int = 1,
    ):
        if batch_size % shard_count != 0:
            raise ValueError(
                f"global batch {batch_size} not divisible by shard_count {shard_count}"
            )
        self.dataset = dataset
        self.batch_size = int(batch_size)
        self.epoch = int(epoch)
        self.seed = int(seed)
        self.start_batch = int(start_batch)
        self.shard_index = int(shard_index)
        self.shard_count = int(shard_count)
        n = len(dataset)
        self.num_batches = n // batch_size if drop_remainder else -(-n // batch_size)
        self._perm = epoch_permutation(n, seed, epoch)

    def __len__(self) -> int:
        return max(self.num_batches - self.start_batch, 0)

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        per_shard = self.batch_size // self.shard_count
        for b in range(self.start_batch, self.num_batches):
            lo = b * self.batch_size + self.shard_index * per_shard
            idx = self._perm[lo : lo + per_shard]
            yield self.dataset.get(idx)


def put_global_batch(batch: dict[str, np.ndarray], sharding=None) -> dict[str, jax.Array]:
    """Device-put a host batch; with a NamedSharding this becomes the
    host-local shard of a global array (multi-host) or a sharded array
    (single-host multi-device)."""
    if sharding is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    return {k: jax.device_put(v, sharding) for k, v in batch.items()}


def prefetch(batches, put=put_global_batch, *, depth: int = 2):
    """Double-buffered device feed: ``put`` (device transfer) of batch *b+1*
    is issued while step *b* executes.

    jax dispatch is async, so holding ``depth`` already-transferred batches
    ahead of the consumer overlaps host->device copies with device compute —
    the consumer never waits on a cold transfer. ``depth=1`` degenerates to
    the unbuffered ``put``-per-iteration loop; the yielded values (and
    therefore the training trajectory) are identical either way, only the
    transfer timing moves.
    """
    if depth < 1:
        raise ValueError(f"prefetch depth must be >= 1, got {depth}")
    buf: collections.deque = collections.deque()
    for b in batches:
        buf.append(put(b))
        if len(buf) >= depth:
            yield buf.popleft()
    while buf:
        yield buf.popleft()


def microbatches(batch: dict[str, np.ndarray], micro_size: int):
    """Split a (host-side) batch into microbatches along axis 0."""
    n = len(next(iter(batch.values())))
    if n % micro_size != 0:
        raise ValueError(f"batch {n} not divisible by microbatch {micro_size}")
    for i in range(0, n, micro_size):
        yield {k: v[i : i + micro_size] for k, v in batch.items()}
