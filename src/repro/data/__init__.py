from repro.data.pipeline import Cursor, EpochLoader, epoch_permutation, microbatches, prefetch, put_global_batch
from repro.data.synthetic import ArrayDataset, TokenStream, imagelike_classification, sigmoid_synthetic

__all__ = [
    "ArrayDataset",
    "TokenStream",
    "sigmoid_synthetic",
    "imagelike_classification",
    "Cursor",
    "EpochLoader",
    "epoch_permutation",
    "microbatches",
    "prefetch",
    "put_global_batch",
]
