"""The paper's synthetic dataset (Section 5.1, eq. 3) plus LM/image analogues.

All generators are deterministic functions of a seed, chunk-addressable, and
cheap — so every data-parallel host materialises exactly its own shard, and a
restarted job regenerates identical batches (fault-tolerance requirement).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ArrayDataset:
    """In-memory dataset of parallel arrays (leading axis = samples)."""

    arrays: dict[str, np.ndarray]

    def __len__(self) -> int:
        return len(next(iter(self.arrays.values())))

    def get(self, indices: np.ndarray) -> dict[str, np.ndarray]:
        return {k: v[indices] for k, v in self.arrays.items()}


def sigmoid_synthetic(
    n: int = 20_000, d: int = 512, noise: float = 0.1, seed: int = 0
) -> tuple[ArrayDataset, ArrayDataset, np.ndarray]:
    """y = 1{ sigma(w* . x + eps) > 0.5 },  x ~ U[-1,1]^d,  eps ~ N(0, noise).

    Returns (train 80%, val 20%, w_star) exactly as in the paper.
    """
    rng = np.random.default_rng(seed)
    w_star = rng.standard_normal(d).astype(np.float32)
    x = rng.uniform(-1.0, 1.0, size=(n, d)).astype(np.float32)
    eps = rng.normal(0.0, noise, size=n).astype(np.float32)
    logits = x @ w_star + eps
    prob = 1.0 / (1.0 + np.exp(-logits))
    y = (prob > 0.5).astype(np.int32)
    split = int(n * 0.8)
    train = ArrayDataset({"x": x[:split], "y": y[:split]})
    val = ArrayDataset({"x": x[split:], "y": y[split:]})
    return train, val, w_star


def imagelike_classification(
    n: int = 10_000,
    num_classes: int = 10,
    hw: int = 32,
    channels: int = 3,
    noise: float = 0.35,
    template_rank: int = 6,
    seed: int = 0,
) -> tuple[ArrayDataset, ArrayDataset]:
    """CIFAR-shaped procedural classification task.

    Each class has a low-rank spatial template; a sample is its class template
    mixed with sample-specific low-rank clutter and pixel noise. Low-rank
    structure gives convnets a real (learnable, non-trivial) decision problem,
    so gradient diversity behaves like on natural images: high early, falling
    as the model fits the shared structure.
    """
    rng = np.random.default_rng(seed)
    # class templates: sum of outer products of smooth vectors
    def smooth(k):
        v = rng.standard_normal((k, hw)).astype(np.float32)
        kernel = np.hanning(7).astype(np.float32)
        kernel /= kernel.sum()
        return np.stack([np.convolve(vi, kernel, mode="same") for vi in v])

    templates = np.zeros((num_classes, hw, hw, channels), np.float32)
    for c in range(num_classes):
        for ch in range(channels):
            u, v = smooth(template_rank), smooth(template_rank)
            templates[c, :, :, ch] = (u.T @ v) / np.sqrt(template_rank)

    y = rng.integers(0, num_classes, size=n).astype(np.int32)
    clutter_u, clutter_v = smooth(2), smooth(2)
    x = templates[y]
    mix = rng.standard_normal((n, 1, 1, 1)).astype(np.float32) * 0.15
    x = x + mix * (clutter_u.T @ clutter_v)[None, :, :, None]
    x = x + rng.normal(0.0, noise, size=x.shape).astype(np.float32)
    x = x.astype(np.float32)
    split = int(n * 0.9)
    return (
        ArrayDataset({"x": x[:split], "y": y[:split]}),
        ArrayDataset({"x": x[split:], "y": y[split:]}),
    )


class TokenStream:
    """Deterministic synthetic LM corpus: order-1 Markov chain over a Zipfian
    vocabulary. Chunk-addressable: ``tokens(start, length)`` is a pure function
    of (seed, start), so any host can materialise any window independently.
    """

    def __init__(self, vocab_size: int, seed: int = 0, branch: int = 64):
        self.vocab_size = int(vocab_size)
        self.seed = int(seed)
        self.branch = int(branch)
        rng = np.random.default_rng(seed)
        # per-state successor table (sparse transition structure)
        self._succ = rng.integers(
            0, vocab_size, size=(min(vocab_size, 4096), branch), dtype=np.int64
        )
        zipf = 1.0 / np.arange(1, branch + 1) ** 1.1
        self._probs = (zipf / zipf.sum()).astype(np.float64)

    def tokens(self, start: int, length: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, start))
        out = np.empty(length, np.int32)
        state = int(rng.integers(0, self._succ.shape[0]))
        choices = rng.choice(self.branch, size=length, p=self._probs)
        for i in range(length):
            nxt = int(self._succ[state % self._succ.shape[0], choices[i]])
            out[i] = nxt % self.vocab_size
            state = nxt % self._succ.shape[0]
        return out

    def batch(self, step: int, batch_size: int, seq_len: int) -> dict[str, np.ndarray]:
        """(batch, seq+1) tokens -> {'tokens': (B,S), 'targets': (B,S)}."""
        span = seq_len + 1
        base = step * batch_size * span
        toks = np.stack(
            [self.tokens(base + b * span, span) for b in range(batch_size)]
        )
        return {"tokens": toks[:, :-1].astype(np.int32), "targets": toks[:, 1:].astype(np.int32)}
